"""MoE DM/DC/DevMem sweep over expert count x capacity factor (ROADMAP
item): EXACT composed replays of 2-layer expert-routed FFN stacks —
practical only with the compiled replay engine (steady-state sampling
previously stood in for anything this size).  Shows how routing width
and capacity headroom move the Fig.-2 buckets per memory mode."""
import time

from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay
from repro.accesys.system import default_system
from repro.core import plan as plan_ir
from repro.models.moe import routed_capacity
from benchmarks.common import emit

N_TOKENS, D_MODEL, D_FF, TOP_K, LAYERS = 256, 256, 512, 2, 2


def moe_stack(n_experts: int, capacity_factor: float):
    return plan_ir.concat(
        [plan_ir.moe_layer_plan(
            N_TOKENS, D_MODEL, n_experts, TOP_K, D_FF, "int8",
            capacity_factor=capacity_factor, layer=i,
            x="x" if i == 0 else f"M{i-1}.out")
         for i in range(LAYERS)],
        name=f"moe_E{n_experts}_cf{capacity_factor}")


def main():
    rows = []
    t0 = time.perf_counter()
    for n_experts in (4, 8, 16):
        for cf in (1.0, 1.25, 1.5):
            plan = moe_stack(n_experts, cf)
            cap = routed_capacity(N_TOKENS * TOP_K, n_experts, None, cf)
            for mode, dram in (("DM", None), ("DC", None),
                               ("DevMem", DRAM("HBM2"))):
                r = replay(default_system(mode, dram=dram), plan,
                           engine="compiled")
                b = r.buckets()
                rows.append((
                    f"E{n_experts}.cf{cf}.{mode}",
                    round(r.total_s * 1e6, 1),
                    f"capacity={cap};events={len(plan.events)};"
                    f"transfer_share={b['transfer']:.3f};"
                    f"host_share={b['host']:.3f};"
                    f"tlb_miss={r.tlb_misses}"))
    print(f"# 27 exact composed replays in "
          f"{time.perf_counter() - t0:.1f}s (compiled engine)")
    emit(rows, "moe_sweep")


if __name__ == "__main__":
    main()
