"""MoE DM/DC/DevMem sweep over expert count x capacity factor (ROADMAP
item): EXACT composed replays of 2-layer expert-routed FFN stacks —
practical only with the compiled replay engine.  Shows how routing
width and capacity headroom move the Fig.-2 buckets per memory mode.
Each (E, cf) cell is one Scenario; ``sweep`` shares the lowered plan
(and its compiled form) across the three memory modes."""
import time

from repro.core.scenario import Scenario, as_params, sweep
from repro.models.moe import routed_capacity
from benchmarks.common import emit, simresult_rows

N_TOKENS, D_MODEL, D_FF, TOP_K, LAYERS = 256, 256, 512, 2, 2
MODES = ("DM", "DC", "DevMem")


def main():
    rows = []
    t0 = time.perf_counter()
    n_cells = 0
    for n_experts in (4, 8, 16):
        for cf in (1.0, 1.25, 1.5):
            cap = routed_capacity(N_TOKENS * TOP_K, n_experts, None, cf)
            scs = [Scenario(
                model="moe", sampling="exact", n_layers=LAYERS,
                engine="compiled", mode=mode,
                params=as_params(n_tokens=N_TOKENS, d_model=D_MODEL,
                                 d_ff=D_FF, top_k=TOP_K,
                                 n_experts=n_experts,
                                 capacity_factor=cf))
                for mode in MODES]
            results = sweep(scs)
            n_cells += len(results)
            rows += simresult_rows(
                results,
                namer=lambda r, E=n_experts, cf=cf:
                    f"E{E}.cf{cf}.{r.mode}",
                keys=("transfer", "host"),
                extra=lambda r, cap=cap:
                    f"capacity={cap};events={r.events_replayed};"
                    f"tlb_miss={r.result.tlb_misses}")
    print(f"# {n_cells} exact composed replays in "
          f"{time.perf_counter() - t0:.1f}s (compiled engine)")
    emit(rows, "moe_sweep")


if __name__ == "__main__":
    main()
