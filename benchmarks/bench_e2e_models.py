"""Table 9: end-to-end transformer speedups vs baselines (incl. the
published TiC-SAT / SMAUG comparison rows), plus the composed
StreamPlan replay of the full forward pass per mode — and plan-timed
MoE / SSM layer-stack rows across DM/DC/DevMem.  All simulator rows
route through the Scenario API (one plan shared per DM/DC/DevMem
sweep)."""
from repro.accesys import workloads as W
from repro.accesys.system import (SMAUG_SPEEDUP, TICSAT_SPEEDUP,
                                  default_system, run_transformer_accel,
                                  run_transformer_cpu)
from repro.accesys.calibration import PAPER_TABLE9
from repro.core.scenario import Scenario, sweep
from benchmarks.common import emit, simresult_rows

MODES = ("DM", "DC", "DevMem")


def main():
    rows = []
    for name, paper in PAPER_TABLE9.items():
        wl = W.transformer_trace(name)
        acc = run_transformer_accel(default_system("DC"), wl)
        base = run_transformer_cpu(wl)
        mt = run_transformer_cpu(wl, threads=256)
        sp = base.total_s / acc.total_s
        rows.append((f"{name}.matrixflow", round(acc.total_s * 1e6, 1),
                     f"speedup={sp:.1f}x;paper={paper};"
                     f"err={abs(sp-paper)/paper*100:.1f}%"))
        rows.append((f"{name}.multithread", round(mt.total_s * 1e6, 1),
                     f"speedup={base.total_s/mt.total_s:.1f}x"))
        if name in TICSAT_SPEEDUP:
            rows.append((f"{name}.ticsat", "-",
                         f"published_speedup={TICSAT_SPEEDUP[name]}x"))
        if name in SMAUG_SPEEDUP:
            rows.append((f"{name}.smaug", "-",
                         f"published_speedup={SMAUG_SPEEDUP[name]}x"))
    # composed event-graph replay: one StreamPlan timeline across
    # QKV / per-head attention / FFN (2 layers keep the graph small;
    # per-layer cost is uniform, so this is the per-layer latency x2)
    rows += simresult_rows(
        sweep([Scenario(model="bert-medium", n_layers=2,
                        sampling="exact", mode=m) for m in MODES]),
        namer=lambda r: f"bert-medium.composed2.{r.mode}")
    # plan-timed MoE / SSM layer stacks (steady-state sampled: one
    # layer window x 4), same Fig.-2 bucket machinery as dense rows
    for cls in ("moe", "ssm"):
        rows += simresult_rows(
            sweep([Scenario(model=cls, n_layers=4, mode=m)
                   for m in MODES]),
            namer=lambda r, cls=cls: f"{cls}.composed4.{r.mode}",
            events=True)
    emit(rows, "table9_e2e")


if __name__ == "__main__":
    main()
