"""Table 9: end-to-end transformer speedups vs baselines (incl. the
published TiC-SAT / SMAUG comparison rows), plus the composed
StreamPlan replay of the full forward pass per mode — and plan-timed
MoE / SSM layer-stack rows across DM/DC/DevMem."""
from repro.accesys import workloads as W
from repro.accesys.pipeline import replay
from repro.accesys.system import (SMAUG_SPEEDUP, TICSAT_SPEEDUP,
                                  default_system, run_transformer_accel,
                                  run_transformer_composed,
                                  run_transformer_cpu)
from repro.accesys.calibration import PAPER_TABLE9
from repro.accesys.components import DRAM
from repro.core import plan as plan_ir
from benchmarks.common import emit


def main():
    rows = []
    for name, paper in PAPER_TABLE9.items():
        wl = W.transformer_trace(name)
        acc = run_transformer_accel(default_system("DC"), wl)
        base = run_transformer_cpu(wl)
        mt = run_transformer_cpu(wl, threads=256)
        sp = base.total_s / acc.total_s
        rows.append((f"{name}.matrixflow", round(acc.total_s * 1e6, 1),
                     f"speedup={sp:.1f}x;paper={paper};"
                     f"err={abs(sp-paper)/paper*100:.1f}%"))
        rows.append((f"{name}.multithread", round(mt.total_s * 1e6, 1),
                     f"speedup={base.total_s/mt.total_s:.1f}x"))
        if name in TICSAT_SPEEDUP:
            rows.append((f"{name}.ticsat", "-",
                         f"published_speedup={TICSAT_SPEEDUP[name]}x"))
        if name in SMAUG_SPEEDUP:
            rows.append((f"{name}.smaug", "-",
                         f"published_speedup={SMAUG_SPEEDUP[name]}x"))
    # composed event-graph replay: one StreamPlan timeline across
    # QKV / per-head attention / FFN (2 layers keep the graph small;
    # per-layer cost is uniform, so this is the per-layer latency x2)
    for mode, dram in (("DM", None), ("DC", None),
                       ("DevMem", DRAM("HBM2"))):
        r = run_transformer_composed(
            default_system(mode, dram=dram), "bert-medium", n_layers=2)
        rows.append((f"bert-medium.composed2.{mode}",
                     round(r.total_s * 1e6, 1),
                     f"host_share={r.buckets()['host']:.3f};"
                     f"exposed_share={r.buckets()['transfer']:.3f}"))
    # plan-timed MoE / SSM layer stacks (steady-state sampled: one layer
    # window x 4), same Fig.-2 bucket machinery as the dense rows
    moe = plan_ir.moe_layer_plan(64, 128, 8, 2, 256, "int8")
    ssm = plan_ir.ssm_layer_plan(128, 128, 4, "int8", chunk=16)
    for cls, layer in (("moe", moe), ("ssm", ssm)):
        sched = plan_ir.PlanSchedule(f"{cls}_x4", [(layer, 4)])
        for mode, dram in (("DM", None), ("DC", None),
                           ("DevMem", DRAM("HBM2"))):
            r = replay(default_system(mode, dram=dram), sched)
            rows.append((f"{cls}.composed4.{mode}",
                         round(r.total_s * 1e6, 1),
                         f"host_share={r.buckets()['host']:.3f};"
                         f"exposed_share={r.buckets()['transfer']:.3f};"
                         f"events={sched.sampled_events}/"
                         f"{sched.exact_events}"))
    emit(rows, "table9_e2e")


if __name__ == "__main__":
    main()
