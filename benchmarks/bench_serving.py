"""Measured serving throughput of the continuous-batching engine on a
reduced model (real wall-clock on this host), plus plan-timed decode
steps over a live paged KV cache across DM/DC/DevMem (simulated accesys
latency — the paper's SMMU/page-table design applied to serving).

The trace rows replay a FULL engine run: ``record_plans=True`` makes
the engine emit one ``decode_step_plan`` per step (page ids from a
shadow PageTable tracking the real batch composition), and the compiled
replay engine prices the whole multi-hundred-step trace per memory mode
in seconds."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay
from repro.accesys.system import default_system
from repro.configs import get_reduced
from repro.core.plan import EventKind
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache
from benchmarks.common import emit


def decode_plan_rows():
    """Plan-timed batched decode: page ids straight from the live page
    tables, replayed against the component models per memory mode."""
    ccfg = PagedCacheConfig(n_pages=128, page_tokens=8, n_kv_heads=4,
                            head_dim=32, max_pages_per_seq=16,
                            dtype="float16")
    cache = PagedKVCache(ccfg, max_seqs=4)
    kv = lambda t: jnp.zeros((t, ccfg.n_kv_heads, ccfg.head_dim),
                             jnp.float16)
    for slot, ln in enumerate((96, 40, 17, 64)):
        if not cache.alloc_seq(slot, ln):
            raise RuntimeError(f"KV pool too small for slot {slot}")
        cache.write_prompt(slot, kv(ln), kv(ln))
    plan = cache.decode_step_plan([0, 1, 2, 3])
    dma_bytes = sum(ev.nbytes for ev in plan.events
                    if ev.kind is EventKind.DMA_IN)
    rows = []
    for mode, dram in (("DM", None), ("DC", None),
                       ("DevMem", DRAM("HBM2"))):
        r = replay(default_system(mode, dtype="fp16", dram=dram), plan)
        rows.append((f"decode_plan.{mode}", round(r.total_s * 1e6, 2),
                     f"kv_bytes={dma_bytes};"
                     f"pages={cache.pages_in_use};"
                     f"transfer_share={r.buckets()['transfer']:.3f}"))
    return rows


def engine_trace_rows(cfg, params):
    """Replay a >=200-step engine trace per memory mode: the engine
    records one decode plan per step; the compiled replayer prices the
    whole trace (real admissions / retirements / page churn) per mode
    in seconds of wall-clock."""
    rng = np.random.default_rng(1)
    eng = ServingEngine(cfg, params, slots=4, max_seq=96,
                        record_plans=True)
    for i in range(28):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(1, 250, size=int(rng.integers(6, 16))
                                ).astype(np.int32),
            max_new_tokens=32))
    eng.run_until_drained(max_steps=2000)
    plans = eng.step_plans
    if len(plans) < 200:
        raise RuntimeError(f"trace too short: {len(plans)} steps")
    rows = []
    for mode, dram in (("DM", None), ("DC", None),
                       ("DevMem", DRAM("HBM2"))):
        sys_cfg = default_system(mode, dtype="fp16", dram=dram)
        t0 = time.perf_counter()
        sim_s = sum(replay(sys_cfg, p, engine="compiled").total_s
                    for p in plans)
        wall = time.perf_counter() - t0
        rows.append((f"trace_replay.{mode}", round(sim_s * 1e6, 1),
                     f"steps={len(plans)};"
                     f"events={sum(len(p.events) for p in plans)};"
                     f"replay_wall_s={wall:.2f};"
                     f"sim_us_per_step={sim_s * 1e6 / len(plans):.2f}"))
    return rows


def main():
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    rows = []
    for slots in (1, 4):
        rng = np.random.default_rng(0)
        eng = ServingEngine(cfg, params, slots=slots, max_seq=96)
        for i in range(8):
            eng.submit(Request(
                uid=i, prompt=rng.integers(1, 250, size=8).astype(np.int32),
                max_new_tokens=8))
        st = eng.run_until_drained()
        rows.append((f"slots{slots}", round(st.wall_s * 1e6, 0),
                     f"tokens_per_s={st.tokens_per_s:.1f};"
                     f"decode_steps={st.decode_steps}"))
    rows += decode_plan_rows()
    rows += engine_trace_rows(cfg, params)
    emit(rows, "serving_throughput")


if __name__ == "__main__":
    main()
