"""Measured serving throughput of the continuous-batching engine on a
reduced model (real wall-clock on this host), plus the request-centric
serving simulation: the engine records a plan trace — one prefill plan
per admission and one multi-layer GQA decode plan per step — and ONE
batched compiled replay prices the whole 200+-step trace per memory
mode (shared page interning, one continuous timeline; no per-step
Python loop over plans), emitting simulated TTFT/TPOT p50/p95/p99
attributed to individual requests."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay
from repro.accesys.system import default_system
from repro.configs import get_reduced
from repro.core.plan import EventKind
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache
from repro.serving.sim_report import (simulate_serving_trace,
                                      trace_schedule)
from benchmarks.common import emit

MODES = (("DM", None), ("DC", None), ("DevMem", "HBM2"))


def decode_plan_rows():
    """Plan-timed batched decode: page ids straight from the live page
    tables, replayed against the component models per memory mode."""
    ccfg = PagedCacheConfig(n_pages=128, page_tokens=8, n_kv_heads=4,
                            head_dim=32, max_pages_per_seq=16,
                            dtype="float16")
    cache = PagedKVCache(ccfg, max_seqs=4)
    kv = lambda t: jnp.zeros((t, ccfg.n_kv_heads, ccfg.head_dim),
                             jnp.float16)
    for slot, ln in enumerate((96, 40, 17, 64)):
        if not cache.alloc_seq(slot, ln):
            raise RuntimeError(f"KV pool too small for slot {slot}")
        cache.write_prompt(slot, kv(ln), kv(ln))
    plan = cache.decode_step_plan([0, 1, 2, 3])
    dma_bytes = sum(ev.nbytes for ev in plan.events
                    if ev.kind is EventKind.DMA_IN)
    rows = []
    for mode, dram in MODES:
        r = replay(default_system(mode, dtype="fp16",
                                  dram=DRAM(dram) if dram else None),
                   plan)
        rows.append((f"decode_plan.{mode}", round(r.total_s * 1e6, 2),
                     f"kv_bytes={dma_bytes};"
                     f"pages={cache.pages_in_use};"
                     f"transfer_share={r.buckets()['transfer']:.3f}"))
    return rows


def engine_trace_rows(cfg, params):
    """Replay a >=200-step engine trace per memory mode as ONE batched
    compiled replay: the engine records one prefill plan per admission
    and one multi-layer GQA decode plan per step; per mode the whole
    trace is priced on one continuous timeline and the per-request
    TTFT/TPOT percentiles are read off it."""
    rng = np.random.default_rng(1)
    eng = ServingEngine(cfg, params, slots=4, max_seq=96,
                        record_plans=True)
    for i in range(28):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(1, 250, size=int(rng.integers(6, 16))
                                ).astype(np.int32),
            max_new_tokens=32))
    eng.run_until_drained(max_steps=2000)
    trace = eng.trace
    decode_steps = sum(1 for r in trace if r.kind == "decode")
    prefills = len(trace) - decode_steps
    if decode_steps < 200:
        raise RuntimeError(f"trace too short: {decode_steps} steps")
    sched = trace_schedule(trace)       # one compile, shared per mode
    rows = []
    for mode, dram in MODES:
        sys_cfg = default_system(mode, dtype="fp16",
                                 dram=DRAM(dram) if dram else None)
        t0 = time.perf_counter()
        rep = simulate_serving_trace(sys_cfg, trace, sched=sched,
                                     engine="compiled")
        wall = time.perf_counter() - t0
        pct = rep.percentiles()
        decode_s = sum(s for s, r in zip(rep.per_event_s, trace)
                       if r.kind == "decode")
        rows.append((f"trace_replay.{mode}",
                     round(rep.total_s * 1e6, 1),
                     f"steps={decode_steps};prefills={prefills};"
                     f"events={sched.sampled_events};"
                     f"replay_wall_s={wall:.2f};"
                     f"sim_us_per_decode_step="
                     f"{decode_s * 1e6 / decode_steps:.2f};"
                     f"prefill_share="
                     f"{1 - decode_s / rep.total_s:.3f}"))
        rows.append((f"serving_latency.{mode}",
                     round(pct["ttft_p50_us"], 1),
                     f"ttft_p95_us={pct['ttft_p95_us']:.1f};"
                     f"ttft_p99_us={pct['ttft_p99_us']:.1f};"
                     f"tpot_p50_us={pct['tpot_p50_us']:.2f};"
                     f"tpot_p95_us={pct['tpot_p95_us']:.2f};"
                     f"tpot_p99_us={pct['tpot_p99_us']:.2f};"
                     f"requests={pct['requests']}"))
    return rows


def main():
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    rows = []
    for slots in (1, 4):
        rng = np.random.default_rng(0)
        eng = ServingEngine(cfg, params, slots=slots, max_seq=96)
        for i in range(8):
            eng.submit(Request(
                uid=i, prompt=rng.integers(1, 250, size=8).astype(np.int32),
                max_new_tokens=8))
        st = eng.run_until_drained()
        rows.append((f"slots{slots}", round(st.wall_s * 1e6, 0),
                     f"tokens_per_s={st.tokens_per_s:.1f};"
                     f"decode_steps={st.decode_steps}"))
    rows += decode_plan_rows()
    rows += engine_trace_rows(cfg, params)
    emit(rows, "serving_throughput")


if __name__ == "__main__":
    main()
