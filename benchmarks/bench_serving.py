"""Measured serving throughput of the continuous-batching engine on a
reduced model (real wall-clock on this host)."""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from benchmarks.common import emit


def main():
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    rows = []
    for slots in (1, 4):
        rng = np.random.default_rng(0)
        eng = ServingEngine(cfg, params, slots=slots, max_seq=96)
        for i in range(8):
            eng.submit(Request(
                uid=i, prompt=rng.integers(1, 250, size=8).astype(np.int32),
                max_new_tokens=8))
        st = eng.run_until_drained()
        rows.append((f"slots{slots}", round(st.wall_s * 1e6, 0),
                     f"tokens_per_s={st.tokens_per_s:.1f};"
                     f"decode_steps={st.decode_steps}"))
    emit(rows, "serving_throughput")


if __name__ == "__main__":
    main()
