"""Measured serving throughput of the continuous-batching engine on a
reduced model (real wall-clock on this host), plus the request-centric
serving simulation routed through the Scenario API: the ``serve``
scenario records an engine plan trace — one prefill plan per admission
and one multi-layer GQA decode plan per step — and ONE batched compiled
replay prices the whole 200+-step trace per memory mode (shared page
interning, one continuous timeline), emitting simulated TTFT/TPOT
p50/p95/p99 attributed to individual requests.  ``sweep`` reuses the
recorded trace (and its compiled schedule) across the three modes."""
import numpy as np

from repro.core.plan import EventKind
from repro.core.scenario import Scenario, as_params, scenario_plan, sweep
from benchmarks.common import emit, simresult_rows

MODES = ("DM", "DC", "DevMem")

# the recorded-trace scenario: 28 requests on 4 slots, prompts 6-15
# tokens, 32 new tokens each -> 28 prefills + 200+ decode steps
SERVE = as_params(arch="qwen2_0_5b", slots=4, n_requests=28,
                  max_new_tokens=32, max_seq=96, prompt_lo=6,
                  prompt_hi=16, seed=1)
# plan-timed batched decode over a live driver-side page table
DECODE = as_params(n_pages=128, page_tokens=8, n_kv_heads=4,
                   head_dim=32, max_pages_per_seq=16,
                   prompt_lens=(96, 40, 17, 64), churn=(),
                   n_q_heads=None)


def decode_plan_rows():
    """Batched decode step: page ids straight from the live page
    tables, replayed against the component models per memory mode."""
    scs = [Scenario(model="decode", dtype="fp16", mode=m,
                    params=DECODE) for m in MODES]
    plan, _, _, _ = scenario_plan(scs[0])
    dma_bytes = sum(ev.nbytes for ev in plan.events
                    if ev.kind is EventKind.DMA_IN)
    # distinct pool pages the plan streams (page key = (tensor, pid);
    # K and V pools share the same page-id set)
    pages = len({ev.page[1] for ev in plan.events
                 if ev.kind is EventKind.DMA_IN})
    return simresult_rows(
        sweep(scs), namer=lambda r: f"decode_plan.{r.mode}",
        keys=("transfer",),
        extra=lambda r: f"kv_bytes={dma_bytes};pages={pages}")


def engine_trace_rows():
    """Replay a >=200-step engine trace per memory mode as ONE batched
    compiled replay and read the per-request TTFT/TPOT percentiles off
    the continuous timeline."""
    results = sweep([Scenario(model="serve", dtype="fp16", mode=m,
                              engine="compiled", params=SERVE)
                     for m in MODES])
    sv = results[0].serving
    if sv["decode_steps"] < 200:
        raise RuntimeError(f"trace too short: {sv['decode_steps']} steps")
    rows = []
    for r in results:
        sv = r.serving
        rows.append((f"trace_replay.{r.mode}",
                     round(r.total_s * 1e6, 1),
                     f"steps={sv['decode_steps']};"
                     f"prefills={sv['prefills']};"
                     f"events={r.events_replayed};"
                     f"replay_wall_s={r.wall_s:.2f};"
                     f"sim_us_per_decode_step="
                     f"{sv['sim_us_per_decode_step']:.2f};"
                     f"prefill_share={sv['prefill_share']:.3f}"))
        rows.append((f"serving_latency.{r.mode}",
                     round(sv["ttft_p50_us"], 1),
                     f"ttft_p95_us={sv['ttft_p95_us']:.1f};"
                     f"ttft_p99_us={sv['ttft_p99_us']:.1f};"
                     f"tpot_p50_us={sv['tpot_p50_us']:.2f};"
                     f"tpot_p95_us={sv['tpot_p95_us']:.2f};"
                     f"tpot_p99_us={sv['tpot_p99_us']:.2f};"
                     f"requests={sv['requests']}"))
    return rows


def main():
    import jax
    from repro.configs import get_reduced
    from repro.models.model import Model
    from repro.serving.engine import Request, ServingEngine
    # the serve scenario initializes its own reduced model inside the
    # scenario trace cache (self-contained across callers); the rows
    # below measure REAL engine wall-clock, so they need their own
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    rows = []
    for slots in (1, 4):
        rng = np.random.default_rng(0)
        eng = ServingEngine(cfg, params, slots=slots, max_seq=96)
        for i in range(8):
            eng.submit(Request(
                uid=i, prompt=rng.integers(1, 250, size=8).astype(np.int32),
                max_new_tokens=8))
        st = eng.run_until_drained()
        rows.append((f"slots{slots}", round(st.wall_s * 1e6, 0),
                     f"tokens_per_s={st.tokens_per_s:.1f};"
                     f"decode_steps={st.decode_steps}"))
    rows += decode_plan_rows()
    rows += engine_trace_rows()
    emit(rows, "serving_throughput")


if __name__ == "__main__":
    main()
