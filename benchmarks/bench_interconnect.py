"""Fig. 12 (+ wall-clock paragraph): memory locations × interconnects."""
from repro.accesys import workloads as W
from repro.accesys.components import DRAM
from repro.accesys.system import (default_system, pcie_for_bw,
                                  run_transformer_accel)
from benchmarks.common import emit


def main():
    rows = []
    for model in ("vit-base-16", "vit-large-16", "vit-huge-14"):
        wl = W.transformer_trace(model)
        ts = {}
        for bw in (2, 8, 64):
            ts[bw] = run_transformer_accel(
                default_system("DC", pcie=pcie_for_bw(bw)), wl).total_s
        dev = run_transformer_accel(
            default_system("DevMem", dram=DRAM("HBM2"),
                           pcie=pcie_for_bw(64)), wl).total_s
        for bw, t in ts.items():
            rows.append((f"{model}.host{bw}GBs", round(t * 1e6, 1),
                         f"norm_vs_2GBs={ts[2] / t:.2f}x"))
        rows.append((f"{model}.devmem_hbm2", round(dev * 1e6, 1),
                     f"host64_vs_devmem={dev / ts[64]:.2f}x"))
    emit(rows, "fig12_interconnect")


if __name__ == "__main__":
    main()
