"""Fig. 12 (+ wall-clock paragraph): memory locations × interconnects,
priced through the Scenario API (``pcie_gb_s`` is a pricing-time knob,
so every bandwidth point reuses one lowered plan).  A tensor-parallel
row rides along: the same host-64GB/s point sharded tp=2 over a
64 GB/s ring, showing what device-to-device collectives cost next to
the host link the figure sweeps.
"""
import dataclasses

from repro.core.scenario import Scenario, simulate

try:
    from benchmarks.common import emit
except ImportError:                    # run as a bare script
    from common import emit


def main():
    rows = []
    for model in ("vit-base-16", "vit-large-16", "vit-huge-14"):
        base = Scenario(model=model, mode="DC")
        ts = {}
        for bw in (2, 8, 64):
            ts[bw] = simulate(dataclasses.replace(
                base, pcie_gb_s=float(bw))).total_s
        dev = simulate(dataclasses.replace(
            base, mode="DevMem", devmem_dram="HBM2",
            pcie_gb_s=64.0)).total_s
        for bw, t in ts.items():
            rows.append((f"{model}.host{bw}GBs", round(t * 1e6, 1),
                         f"norm_vs_2GBs={ts[2] / t:.2f}x"))
        rows.append((f"{model}.devmem_hbm2", round(dev * 1e6, 1),
                     f"host64_vs_devmem={dev / ts[64]:.2f}x"))
        shard = simulate(dataclasses.replace(
            base, pcie_gb_s=64.0, tp=2, fabric="ring:64"))
        rows.append((f"{model}.host64GBs.tp2_ring64",
                     round(shard.total_s * 1e6, 1),
                     f"vs_tp1={ts[64] / shard.total_s:.2f}x;"
                     f"coll_share="
                     f"{shard.buckets()['collective']:.4f}"))
    emit(rows, "fig12_interconnect")


if __name__ == "__main__":
    main()
