"""Fig. 7a: 512x512 GEMM throughput across data precisions & platforms."""
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import CPUModel, default_system
from benchmarks.common import emit


def main():
    cpu = CPUModel()
    rows = []
    for dtype in ("int8", "int16", "int32", "fp16", "fp32"):
        n = 512
        macs = n ** 3
        base = cpu.gemm_time(macs, dtype)
        for name, t in [
            ("cpu1", base),
            ("omp256", cpu.gemm_time(macs, dtype, threads=256)),
            ("neon", cpu.gemm_time(macs, dtype, simd=True)),
            ("matrixflow_dc", simulate_gemm(
                default_system("DC", dtype=dtype), n, n, n).total_s),
            ("matrixflow_dm", simulate_gemm(
                default_system("DM", dtype=dtype), n, n, n).total_s),
        ]:
            rows.append((f"{dtype}.{name}", round(t * 1e6, 3),
                         f"speedup={base / t:.1f}x"))
    emit(rows, "fig7a_gemm_precision")


if __name__ == "__main__":
    main()
