"""Open-loop serving scale benchmark: chunk-streamed trace pricing.

Generates seeded Poisson open-loop serving traces with the plan-only
``ServingEngine`` (no JAX, no weights — just the plan stream a real
run would record) and prices them for all three memory modes in ONE
``replay_trace_streamed`` pass:

  * ``serve_1k``  — 1,000 requests; also re-priced monolithically
    (``replay_trace``) under ``tracemalloc`` on both paths, so the
    artifact records the peak-allocation ratio that demonstrates the
    O(chunk) memory claim, plus the prefix-caching on/off delta;
  * ``serve_10k`` — 10,000 requests (multi-million events).  The
    trace is never materialized: the engine record generator feeds
    the replayer through the zero-arg factory form, one pass to
    discover the footprint, one to price, O(chunk) live memory;
  * ``serve_preempt_1k`` — the 1k workload on a pressure-capped KV
    pool with ``preempt="lifo"``: admission stalls evict victims and
    the trace carries their swap-out/swap-in DMA, pricing the
    swap-thrash regime end to end;
  * ``serve_10k_templated`` — the 10k workload with template-compiled
    plan instancing (``ServingEngine(templated=True)``): structurally
    identical decode/prefill/swap steps share ONE compiled skeleton
    and per-step records are cheap page-id relabels.  The row must be
    bitwise identical to ``serve_10k`` (``GemmResult ==``) and its
    end-to-end (build + price) wall-clock is the headline speedup;
  * ``load_sweep_200`` — a 3-rate ``sweep_load`` priced three ways
    (event-built serial, templated serial, templated parallel
    workers) with byte-identical ``loadsweep/v1`` JSON across all
    three.

Per workload, wall-clock is split into phases: ``gen_s`` (trace
build: engine record walk), ``compile_s`` (chunk compilation share),
``price_only_s`` (the replay engine's own share).

Writes the usual CSV rows plus ``BENCH_serving_scale.json`` at the
repo root (schema ``serving_scale/v2``) — events/sec and wall-clock
per workload, consumed by ``check_replay_trajectory.py`` as a
host-normalized >2x regression gate on the streaming path and an
artifact-level (same-host ratio) gate on the templating speedup.
"""
import json
import os
import resource
import time
import tracemalloc
from pathlib import Path

import numpy as np

from repro.accesys.pipeline import (release_scratch, replay_trace,
                                    replay_trace_streamed)
from repro.configs import get_reduced
from repro.core.plan import (_plan_n_events, compile_trace_chunks,
                             trace_footprint)
from repro.core.scenario import (MODES, Scenario, sweep_load,
                                 system_for)
from repro.serving.engine import Request, ServingEngine, arrival_times

try:
    from benchmarks.common import emit, write_json_artifact
except ImportError:                      # run as a script from anywhere
    from common import emit, write_json_artifact

JSON_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_serving_scale.json"

CHUNK_EVENTS = 262_144
QPS = 500.0
SEED = 0
ENGINE_KW = dict(slots=8, max_seq=64, kv_page_tokens=8)
RUN_KW = dict(est_step_s=1e-4, est_prefill_s_per_token=1e-5,
              prefill_chunk_tokens=16)
# memory-pressure variant: the pool holds just TWO worst-case
# requests (2 pages each) so admission stalls preempt + swap instead
# of merely deferring
PREEMPT_ENGINE_KW = dict(kv_pool_pages=4)
PREEMPT_RUN_KW = dict(preempt="lifo")


def build_requests(n: int, seed: int = SEED) -> list:
    rng = np.random.default_rng(seed + 1)
    return [Request(
        uid=i,
        prompt=rng.integers(1, 250, size=int(rng.integers(8, 12))
                            ).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 4)))
        for i in range(n)]


def mk_engine(prefix_tokens: int = 0, caching: bool = False,
              **engine_kw) -> ServingEngine:
    return ServingEngine(get_reduced("qwen2_0_5b"), plan_only=True,
                         prefix_tokens=prefix_tokens,
                         prefix_caching=caching,
                         **{**ENGINE_KW, **engine_kw})


def record_stream(n: int, seed: int = SEED, run_kw=None, **engine_kw):
    """A FRESH engine + open-loop record generator — deterministic,
    so successive calls replay the identical trace without ever
    holding it in memory."""
    eng = mk_engine(**engine_kw)
    arr = arrival_times("poisson", n, QPS, seed=seed)
    return eng, eng.open_loop_records(build_requests(n, seed), arr,
                                      **{**RUN_KW, **(run_kw or {})})


def stream_price(n: int, cfgs, run_kw=None, **engine_kw):
    """Three-phase O(chunk) pricing of the n-request trace: pass 1
    walks the record stream for the footprint + counts (trace build),
    pass 2 times chunk compilation over a fresh stream, pass 3 streams
    the plans straight into the chunked replayer.  Each pass
    regenerates the trace, so compile and price shares are the
    differences between the passes."""
    counts = {"records": 0, "events": 0}
    engines = []

    def plans_pass1():
        eng, gen = record_stream(n, run_kw=run_kw, **engine_kw)
        engines.append(eng)
        for rec in gen:
            counts["records"] += 1
            counts["events"] += _plan_n_events(rec.plan)
            yield rec.plan

    t0 = time.perf_counter()
    foot = trace_footprint(plans_pass1())
    gen_s = time.perf_counter() - t0
    counts["preemptions"] = engines[0].stats.preemptions
    counts["swapped_pages"] = engines[0].stats.swapped_pages

    def factory():
        _, gen = record_stream(n, run_kw=run_kw, **engine_kw)
        return (rec.plan for rec in gen)

    t0 = time.perf_counter()
    for _ in compile_trace_chunks(factory(), chunk_events=CHUNK_EVENTS):
        pass
    compile_pass_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    results, _ = replay_trace_streamed(cfgs, factory,
                                       footprint_pages=foot,
                                       chunk_events=CHUNK_EVENTS)
    price_s = time.perf_counter() - t0
    phases = {"compile_s": round(max(compile_pass_s - gen_s, 0.0), 3),
              "price_only_s": round(max(price_s - compile_pass_s, 0.0),
                                    3)}
    return results, foot, counts, gen_s, price_s, phases


def peak_mb(fn):
    tracemalloc.start()
    fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak / 2**20


def main():
    rows = []
    report = {"schema": "serving_scale/v2", "chunk_events": CHUNK_EVENTS,
              "qps": QPS, "engine": ENGINE_KW, "workloads": {}}
    cfgs = [system_for(Scenario(model="serve", mode=m)) for m in MODES]

    # baseline rows rebuild every plan as a fresh event graph
    # (templated=False); the *_templated row is the same trace as
    # template instances — GemmResults must match bitwise
    workloads = (
        ("serve_1k", 1_000, None, dict(templated=False)),
        ("serve_10k", 10_000, None, dict(templated=False)),
        ("serve_10k_templated", 10_000, None, dict(templated=True)),
        ("serve_preempt_1k", 1_000, PREEMPT_RUN_KW,
         dict(templated=False, **PREEMPT_ENGINE_KW)),
    )
    results_by_name = {}
    for name, n, run_kw, engine_kw in workloads:
        results, foot, counts, gen_s, price_s, phases = stream_price(
            n, cfgs, run_kw=run_kw, **engine_kw)
        results_by_name[name] = results
        ev = counts["events"]
        # the factory regenerates the plan stream inside the priced
        # pass; pass 1 measured that generation cost alone, so the
        # replay engine's own share is the difference
        replay_s = max(price_s - gen_s, 1e-9)
        evs = len(MODES) * ev / replay_s
        wl = {"requests": n, "records": counts["records"],
              "events": ev, "footprint_pages": foot,
              "templated": engine_kw.get("templated", False),
              "gen_s": round(gen_s, 3), **phases,
              "price_s_all_modes": round(price_s, 3),
              "replay_s_all_modes": round(replay_s, 3),
              "per_mode_s": round(replay_s / len(MODES), 3),
              "events_per_s": round(evs),
              "total_s": {m: r.total_s
                          for m, r in zip(MODES, results)}}
        if run_kw:
            wl["preempt"] = run_kw.get("preempt", "none")
            wl["kv_pool_pages"] = engine_kw.get("kv_pool_pages")
            wl["preemptions"] = counts["preemptions"]
            wl["swapped_pages"] = counts["swapped_pages"]
        rows.append((f"{name}.streamed", round(price_s * 1e6, 1),
                     f"events={ev};ev_per_s={evs:,.0f};"
                     f"modes={len(MODES)}"
                     + (f";preemptions={counts['preemptions']}"
                        if run_kw else "")))
        report["workloads"][name] = wl
        release_scratch()

    # templating acceptance: bitwise-identical pricing, >=5x e2e
    assert results_by_name["serve_10k_templated"] == \
        results_by_name["serve_10k"], \
        "templated serve_10k GemmResults diverged from event-built"
    wl10 = report["workloads"]["serve_10k"]
    wl10t = report["workloads"]["serve_10k_templated"]
    e2e = wl10["gen_s"] + wl10["price_s_all_modes"]
    e2e_t = wl10t["gen_s"] + wl10t["price_s_all_modes"]
    wl10t["bitwise_match"] = True
    wl10t["speedup_end_to_end"] = round(e2e / max(e2e_t, 1e-9), 2)
    rows.append(("serve_10k_templated.e2e", round(e2e_t * 1e6, 1),
                 f"speedup={wl10t['speedup_end_to_end']}x;"
                 f"bitwise_match=1"))

    # O(chunk) memory evidence on the 1k trace: peak tracemalloc of
    # the chunked replayer vs the monolithic one on the SAME plans
    eng, gen = record_stream(1_000)
    plans = [rec.plan for rec in gen]
    cfg = cfgs[1]                       # DC
    mono_mb = peak_mb(lambda: replay_trace(cfg, plans))
    release_scratch()
    stream_mb = peak_mb(lambda: replay_trace_streamed(
        cfg, plans, chunk_events=CHUNK_EVENTS))
    release_scratch()
    t0 = time.perf_counter()
    replay_trace(cfg, plans)
    mono_s = time.perf_counter() - t0
    release_scratch()
    report["workloads"]["serve_1k"].update(
        mono_s_one_mode=round(mono_s, 3),
        mono_peak_mb=round(mono_mb, 1),
        streamed_peak_mb=round(stream_mb, 1),
        peak_ratio=round(mono_mb / max(stream_mb, 1e-9), 2))
    rows.append(("serve_1k.peak_mb", round(stream_mb * 1e3, 1),
                 f"mono_mb={mono_mb:.1f};ratio="
                 f"{mono_mb / max(stream_mb, 1e-9):.2f}"))

    # prefix caching: shared 32-token system prompt, measured for free
    pfx = {}
    for label, caching in (("off", False), ("on", True)):
        eng, gen = record_stream(1_000, prefix_tokens=32,
                                 caching=caching)
        plans = [rec.plan for rec in gen]
        res, _ = replay_trace_streamed(cfg, plans,
                                       chunk_events=CHUNK_EVENTS)
        pfx[label] = {"records": len(plans),
                      "events": sum(_plan_n_events(p) for p in plans),
                      "total_s": res.total_s}
        release_scratch()
    report["workloads"]["serve_1k"]["prefix_32tok"] = pfx
    rows.append(("serve_1k.prefix_delta",
                 round((pfx["off"]["total_s"]
                        - pfx["on"]["total_s"]) * 1e6, 1),
                 f"ev_off={pfx['off']['events']};"
                 f"ev_on={pfx['on']['events']}"))

    # parallel load sweep: the same 3-rate sweep priced event-built
    # serial, templated serial, templated parallel — byte-identical
    # loadsweep/v1 JSON across all three, wall-clock is the speedup
    sweep_kw = dict(qps=(100.0, 300.0, 900.0), n_requests=200)
    n_workers = min(4, os.cpu_count() or 1)
    sweeps = {}
    for label, kw in (("event_serial", dict(templated=False)),
                      ("templated_serial", {}),
                      ("templated_workers",
                       dict(workers=n_workers))):
        res = sweep_load(**sweep_kw, **kw)
        j = res.to_json()
        j.pop("wall_s")
        sweeps[label] = {"wall_s": round(res.wall_s, 3), "json": j}
        release_scratch()
    assert sweeps["templated_serial"]["json"] == \
        sweeps["event_serial"]["json"], "templated sweep diverged"
    assert sweeps["templated_workers"]["json"] == \
        sweeps["event_serial"]["json"], "parallel sweep diverged"
    sl_e, sl_t, sl_w = (sweeps[k]["wall_s"] for k in
                        ("event_serial", "templated_serial",
                         "templated_workers"))
    report["workloads"]["load_sweep_200"] = {
        "qps": list(sweep_kw["qps"]),
        "n_requests": sweep_kw["n_requests"],
        "workers": n_workers,
        "event_serial_s": sl_e,
        "templated_serial_s": sl_t,
        "templated_workers_s": sl_w,
        "speedup_templating": round(sl_e / max(sl_t, 1e-9), 2),
        "speedup_end_to_end": round(sl_e / max(sl_w, 1e-9), 2),
        "json_identical": True}
    rows.append(("load_sweep_200.parallel", round(sl_w * 1e6, 1),
                 f"event_serial_s={sl_e};workers={n_workers};"
                 f"speedup={sl_e / max(sl_w, 1e-9):.2f}x"))

    report["rss_peak_mb"] = round(
        resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024, 1)
    emit(rows, "serving_scale")
    JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    write_json_artifact(report, "BENCH_serving_scale")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
