"""Open-loop serving scale benchmark: chunk-streamed trace pricing.

Generates seeded Poisson open-loop serving traces with the plan-only
``ServingEngine`` (no JAX, no weights — just the plan stream a real
run would record) and prices them for all three memory modes in ONE
``replay_trace_streamed`` pass:

  * ``serve_1k``  — 1,000 requests; also re-priced monolithically
    (``replay_trace``) under ``tracemalloc`` on both paths, so the
    artifact records the peak-allocation ratio that demonstrates the
    O(chunk) memory claim, plus the prefix-caching on/off delta;
  * ``serve_10k`` — 10,000 requests (multi-million events).  The
    trace is never materialized: the engine record generator feeds
    the replayer through the zero-arg factory form, one pass to
    discover the footprint, one to price, O(chunk) live memory;
  * ``serve_preempt_1k`` — the 1k workload on a pressure-capped KV
    pool with ``preempt="lifo"``: admission stalls evict victims and
    the trace carries their swap-out/swap-in DMA, pricing the
    swap-thrash regime end to end.

Writes the usual CSV rows plus ``BENCH_serving_scale.json`` at the
repo root (schema ``serving_scale/v1``) — events/sec and wall-clock
per workload, consumed by ``check_replay_trajectory.py`` as a
host-normalized >2x regression gate on the streaming path.
"""
import json
import resource
import time
import tracemalloc
from pathlib import Path

import numpy as np

from repro.accesys.pipeline import (release_scratch, replay_trace,
                                    replay_trace_streamed)
from repro.configs import get_reduced
from repro.core.plan import trace_footprint
from repro.core.scenario import MODES, Scenario, system_for
from repro.serving.engine import Request, ServingEngine, arrival_times

try:
    from benchmarks.common import emit, write_json_artifact
except ImportError:                      # run as a script from anywhere
    from common import emit, write_json_artifact

JSON_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_serving_scale.json"

CHUNK_EVENTS = 262_144
QPS = 500.0
SEED = 0
ENGINE_KW = dict(slots=8, max_seq=64, kv_page_tokens=8)
RUN_KW = dict(est_step_s=1e-4, est_prefill_s_per_token=1e-5,
              prefill_chunk_tokens=16)
# memory-pressure variant: the pool holds just TWO worst-case
# requests (2 pages each) so admission stalls preempt + swap instead
# of merely deferring
PREEMPT_ENGINE_KW = dict(kv_pool_pages=4)
PREEMPT_RUN_KW = dict(preempt="lifo")


def build_requests(n: int, seed: int = SEED) -> list:
    rng = np.random.default_rng(seed + 1)
    return [Request(
        uid=i,
        prompt=rng.integers(1, 250, size=int(rng.integers(8, 12))
                            ).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 4)))
        for i in range(n)]


def mk_engine(prefix_tokens: int = 0, caching: bool = False,
              **engine_kw) -> ServingEngine:
    return ServingEngine(get_reduced("qwen2_0_5b"), plan_only=True,
                         prefix_tokens=prefix_tokens,
                         prefix_caching=caching,
                         **{**ENGINE_KW, **engine_kw})


def record_stream(n: int, seed: int = SEED, run_kw=None, **engine_kw):
    """A FRESH engine + open-loop record generator — deterministic,
    so successive calls replay the identical trace without ever
    holding it in memory."""
    eng = mk_engine(**engine_kw)
    arr = arrival_times("poisson", n, QPS, seed=seed)
    return eng, eng.open_loop_records(build_requests(n, seed), arr,
                                      **{**RUN_KW, **(run_kw or {})})


def stream_price(n: int, cfgs, run_kw=None, **engine_kw):
    """Two-pass O(chunk) pricing of the n-request trace: pass 1 walks
    the record stream for the footprint + counts, pass 2 streams the
    plans straight into the chunked replayer."""
    counts = {"records": 0, "events": 0}
    engines = []

    def plans_pass1():
        eng, gen = record_stream(n, run_kw=run_kw, **engine_kw)
        engines.append(eng)
        for rec in gen:
            counts["records"] += 1
            counts["events"] += len(rec.plan.events)
            yield rec.plan

    t0 = time.perf_counter()
    foot = trace_footprint(plans_pass1())
    gen_s = time.perf_counter() - t0
    counts["preemptions"] = engines[0].stats.preemptions
    counts["swapped_pages"] = engines[0].stats.swapped_pages

    def factory():
        _, gen = record_stream(n, run_kw=run_kw, **engine_kw)
        return (rec.plan for rec in gen)

    t0 = time.perf_counter()
    results, _ = replay_trace_streamed(cfgs, factory,
                                       footprint_pages=foot,
                                       chunk_events=CHUNK_EVENTS)
    price_s = time.perf_counter() - t0
    return results, foot, counts, gen_s, price_s


def peak_mb(fn):
    tracemalloc.start()
    fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak / 2**20


def main():
    rows = []
    report = {"schema": "serving_scale/v1", "chunk_events": CHUNK_EVENTS,
              "qps": QPS, "engine": ENGINE_KW, "workloads": {}}
    cfgs = [system_for(Scenario(model="serve", mode=m)) for m in MODES]

    workloads = (
        ("serve_1k", 1_000, None, {}),
        ("serve_10k", 10_000, None, {}),
        ("serve_preempt_1k", 1_000, PREEMPT_RUN_KW, PREEMPT_ENGINE_KW),
    )
    for name, n, run_kw, engine_kw in workloads:
        results, foot, counts, gen_s, price_s = stream_price(
            n, cfgs, run_kw=run_kw, **engine_kw)
        ev = counts["events"]
        # the factory regenerates the plan stream inside the priced
        # pass; pass 1 measured that generation cost alone, so the
        # replay engine's own share is the difference
        replay_s = max(price_s - gen_s, 1e-9)
        evs = len(MODES) * ev / replay_s
        wl = {"requests": n, "records": counts["records"],
              "events": ev, "footprint_pages": foot,
              "gen_s": round(gen_s, 3),
              "price_s_all_modes": round(price_s, 3),
              "replay_s_all_modes": round(replay_s, 3),
              "per_mode_s": round(replay_s / len(MODES), 3),
              "events_per_s": round(evs),
              "total_s": {m: r.total_s
                          for m, r in zip(MODES, results)}}
        if run_kw:
            wl["preempt"] = run_kw.get("preempt", "none")
            wl["kv_pool_pages"] = engine_kw.get("kv_pool_pages")
            wl["preemptions"] = counts["preemptions"]
            wl["swapped_pages"] = counts["swapped_pages"]
        rows.append((f"{name}.streamed", round(price_s * 1e6, 1),
                     f"events={ev};ev_per_s={evs:,.0f};"
                     f"modes={len(MODES)}"
                     + (f";preemptions={counts['preemptions']}"
                        if run_kw else "")))
        report["workloads"][name] = wl
        release_scratch()

    # O(chunk) memory evidence on the 1k trace: peak tracemalloc of
    # the chunked replayer vs the monolithic one on the SAME plans
    eng, gen = record_stream(1_000)
    plans = [rec.plan for rec in gen]
    cfg = cfgs[1]                       # DC
    mono_mb = peak_mb(lambda: replay_trace(cfg, plans))
    release_scratch()
    stream_mb = peak_mb(lambda: replay_trace_streamed(
        cfg, plans, chunk_events=CHUNK_EVENTS))
    release_scratch()
    t0 = time.perf_counter()
    replay_trace(cfg, plans)
    mono_s = time.perf_counter() - t0
    release_scratch()
    report["workloads"]["serve_1k"].update(
        mono_s_one_mode=round(mono_s, 3),
        mono_peak_mb=round(mono_mb, 1),
        streamed_peak_mb=round(stream_mb, 1),
        peak_ratio=round(mono_mb / max(stream_mb, 1e-9), 2))
    rows.append(("serve_1k.peak_mb", round(stream_mb * 1e3, 1),
                 f"mono_mb={mono_mb:.1f};ratio="
                 f"{mono_mb / max(stream_mb, 1e-9):.2f}"))

    # prefix caching: shared 32-token system prompt, measured for free
    pfx = {}
    for label, caching in (("off", False), ("on", True)):
        eng, gen = record_stream(1_000, prefix_tokens=32,
                                 caching=caching)
        plans = [rec.plan for rec in gen]
        res, _ = replay_trace_streamed(cfg, plans,
                                       chunk_events=CHUNK_EVENTS)
        pfx[label] = {"records": len(plans),
                      "events": sum(len(p.events) for p in plans),
                      "total_s": res.total_s}
        release_scratch()
    report["workloads"]["serve_1k"]["prefix_32tok"] = pfx
    rows.append(("serve_1k.prefix_delta",
                 round((pfx["off"]["total_s"]
                        - pfx["on"]["total_s"]) * 1e6, 1),
                 f"ev_off={pfx['off']['events']};"
                 f"ev_on={pfx['on']['events']}"))

    report["rss_peak_mb"] = round(
        resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024, 1)
    emit(rows, "serving_scale")
    JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    write_json_artifact(report, "BENCH_serving_scale")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
