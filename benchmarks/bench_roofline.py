"""Fig. 9: execution time vs ideal accelerator compute throughput — the
curve flattens once the memory/interconnect roof binds."""
import dataclasses

from repro.accesys.components import SystolicArray, SA_VARIANTS
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import default_system
from benchmarks.common import emit


def main():
    rows = []
    base = None
    # scale the array's clock to sweep "ideal compute throughput"
    for scale in (0.25, 0.5, 1, 2, 4, 8, 16):
        key = ("int8", 16)
        freq, area, power, gops = SA_VARIANTS[key]
        SA_VARIANTS[key] = (freq * scale, area, power, gops * scale)
        try:
            cfg = default_system("DC")
            t = simulate_gemm(cfg, 2048, 2048, 2048).total_s
        finally:
            SA_VARIANTS[key] = (freq, area, power, gops)
        base = base or t
        rows.append((f"compute_x{scale}", round(t * 1e6, 1),
                     f"speedup_vs_x0.25={base / t:.2f}x"))
    emit(rows, "fig9_roofline")


if __name__ == "__main__":
    main()
