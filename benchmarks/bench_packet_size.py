"""Fig. 10: execution time vs PCIe packet size for several link speeds;
the 256 B optimum and the 4096 B stall at low speeds."""
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import default_system, pcie_for_bw
from benchmarks.common import emit


def main():
    rows = []
    for gb_s in (2, 8, 32, 64):
        ts = {}
        for pkt in (64, 128, 256, 512, 1024, 4096):
            cfg = default_system("DM", pcie=pcie_for_bw(gb_s, packet=pkt))
            ts[pkt] = simulate_gemm(cfg, 2048, 2048, 2048).total_s
        best = min(ts, key=ts.get)
        for pkt, t in ts.items():
            rows.append((f"bw{gb_s}GBs.pkt{pkt}", round(t * 1e6, 1),
                         f"vs_256B={t / ts[256]:.3f};best={best}"))
    emit(rows, "fig10_packet_size")


if __name__ == "__main__":
    main()
