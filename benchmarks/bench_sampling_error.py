"""Sampled-vs-exact error bars for heterogeneous steady-state schedules
(ROADMAP sampling follow-on): the compiled engine makes EXACT replays
of composed stacks cheap, so the steady-state assumption can be
measured instead of trusted.  Runs the zamba2-reduced mamba/attention
interleave (one steady window per layer CLASS with its own repeat)
sampled AND exact per memory mode, plus the homogeneous bert-base
stack as a reference point, and records the error in the ``SimResult``
artifact (``sampling_error`` field, schema simresult/v1) written to
``artifacts/bench/sampling_error.json``."""
from repro.core.scenario import Scenario, sampling_error
from benchmarks.common import emit, simresult_row, write_json_artifact

MODES = ("DM", "DC", "DevMem")
CASES = (
    # the heterogeneous target: 4 mamba + 2 shared-attention blocks
    Scenario(model="zamba2-7b-reduced", seq=64, engine="compiled"),
    # homogeneous reference: one window class, 12 repeats
    Scenario(model="bert-base", n_layers=12, engine="compiled"),
)


def main():
    import dataclasses
    rows = []
    artifact = []
    for base in CASES:
        for mode in MODES:
            res = sampling_error(dataclasses.replace(base, mode=mode))
            err = res.sampling_error
            rows.append(simresult_row(
                res, name=f"{base.model}.{mode}",
                keys=("host",),
                extra=f"rel_err_total={err['rel_err_total']:.2e};"
                      f"exact_us={err['exact_total_us']:.1f};"
                      f"events={err['events_sampled']}/"
                      f"{err['events_exact']}"))
            artifact.append(res.to_json())
    path = write_json_artifact(artifact, "sampling_error")
    print(f"# wrote {path}")
    emit(rows, "sampling_error")


if __name__ == "__main__":
    main()
