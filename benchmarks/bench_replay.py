"""Replay-engine benchmark: compiled (array-form) vs event-loop replay
wall-clock and events/sec per workload class, with the exact BERT-Base
composed replay as the headline row.  Every plan is lowered through the
Scenario API (``core.scenario``).

Writes the usual CSV rows plus ``BENCH_replay.json`` at the repo root —
the seed of the perf trajectory: events, per-mode wall-clocks for both
engines, events/sec, plan-build and compile times, the aggregate
speedup across DM/DC/DevMem (the sweep use case; the first compiled
mode pays the one-time trace analysis that later modes reuse), and the
full ``SimResult`` JSON (schema ``simresult/v1``) of each compiled
mode run."""
import dataclasses
import json
import time
from pathlib import Path

from repro.accesys.pipeline import replay
from repro.core import scenario as SC
from repro.core.scenario import Scenario, SimResult, as_params, \
    scenario_plan, system_for
from benchmarks.common import emit

JSON_PATH = Path("BENCH_replay.json")

MODES = ("DM", "DC", "DevMem")

WORKLOADS = [
    ("gemm1024", Scenario(model="gemm",
                          params=as_params(m=1024, n=1024, k=1024))),
    ("bert-base.exact", Scenario(model="bert-base", sampling="exact")),
    ("bert-base.sampled", Scenario(model="bert-base")),
    ("moe.exact_x2", Scenario(model="moe", sampling="exact",
                              n_layers=2)),
    ("ssm.exact_x2", Scenario(model="ssm", sampling="exact",
                              n_layers=2)),
    ("decode_step", Scenario(
        model="decode", dtype="fp16",
        params=as_params(n_pages=256, page_tokens=8, n_kv_heads=8,
                         head_dim=64, max_pages_per_seq=32,
                         prompt_lens=(96, 40, 17, 64, 128, 9, 200, 55),
                         churn=((3, 77),), n_q_heads=None))),
]


def main():
    rows = []
    report = {}
    for name, sc in WORKLOADS:
        t0 = time.perf_counter()
        plan, label, events, total = scenario_plan(sc)
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan.compile()
        compile_s = time.perf_counter() - t0
        wl = {"events": events, "build_s": round(build_s, 4),
              "compile_s": round(compile_s, 4), "modes": {}}
        tot_e = tot_c = 0.0
        for mode in MODES:
            cfg = system_for(dataclasses.replace(sc, mode=mode))
            t0 = time.perf_counter()
            rc = replay(cfg, plan, engine="compiled")
            wall_c = time.perf_counter() - t0
            t0 = time.perf_counter()
            re = replay(cfg, plan, engine="event")
            wall_e = time.perf_counter() - t0
            err = abs(rc.total_s - re.total_s) / max(re.total_s, 1e-30)
            assert err < 1e-9, (name, mode, err)
            tot_e += wall_e
            tot_c += wall_c
            sim = SimResult(
                scenario=dataclasses.replace(sc, mode=mode,
                                             engine="compiled"),
                label=label, mode=mode, engine="compiled", result=rc,
                events_replayed=events, events_total=total,
                wall_s=wall_c)
            wl["modes"][mode] = {
                "event_s": round(wall_e, 4),
                "compiled_s": round(wall_c, 4),
                "event_ev_per_s": round(events / max(wall_e, 1e-9)),
                "compiled_ev_per_s": round(events / max(wall_c, 1e-9)),
                "speedup": round(wall_e / max(wall_c, 1e-9), 2),
                "total_us": round(re.total_s * 1e6, 3),
                "sim": sim.to_json(),
            }
        wl["speedup_all_modes"] = round(tot_e / max(tot_c, 1e-9), 2)
        report[name] = wl
        rows.append((name, round(tot_c / len(MODES) * 1e6, 1),
                     f"events={events};"
                     f"speedup_all_modes={wl['speedup_all_modes']}x;"
                     f"compiled_ev_per_s="
                     f"{round(events * len(MODES) / max(tot_c, 1e-9))}"))
    report["_meta"] = {
        "note": "wall-clock of replay() per engine; compiled modes "
                "share one plan compile + trace analysis (memoized), "
                "so the first mode carries that one-time cost; plans "
                "lowered via core.scenario, per-mode 'sim' entries "
                "follow the simresult/v1 schema",
        "acceptance": "bert-base.exact speedup_all_modes >= 10x",
    }
    JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {JSON_PATH} (bert-base.exact all-modes speedup: "
          f"{report['bert-base.exact']['speedup_all_modes']}x)")
    emit(rows, "replay_engines")
    # drop the exact full-depth graph (order-100 MB with its compiled
    # arrays) so the rest of a benchmarks/run.py session isn't pinning it
    SC.clear_caches()


if __name__ == "__main__":
    main()
