"""Replay-engine benchmark: compiled (array-form) vs event-loop replay
wall-clock and events/sec per workload class, with the exact BERT-Base
composed replay as the headline row.

Writes the usual CSV rows plus ``BENCH_replay.json`` at the repo root —
the seed of the perf trajectory: events, per-mode wall-clocks for both
engines, events/sec, plan-build and compile times, and the aggregate
speedup across DM/DC/DevMem (the sweep use case; the first compiled
mode pays the one-time trace analysis that later modes reuse)."""
import json
import time
from pathlib import Path

from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay
from repro.accesys.system import default_system, model_stream_plan, \
    model_stream_schedule
from repro.core import plan as plan_ir
from repro.serving.kv_cache import PagedCacheConfig, PageTable
from benchmarks.common import emit

JSON_PATH = Path("BENCH_replay.json")

MODES = (("DM", None), ("DC", None), ("DevMem", "HBM2"))


def _decode_trace_plan():
    """A batched decode plan from a churned driver-side PageTable (no
    device pools needed to price serving traffic)."""
    pt = PageTable(PagedCacheConfig(
        n_pages=256, page_tokens=8, n_kv_heads=8, head_dim=64,
        max_pages_per_seq=32, dtype="float16"), max_seqs=8)
    for slot, ln in enumerate((96, 40, 17, 64, 128, 9, 200, 55)):
        if not pt.alloc_seq(slot, ln) or not pt.note_tokens(slot, ln):
            raise RuntimeError(f"KV pool too small for slot {slot}")
    pt.free_seq(3)
    if not pt.alloc_seq(3, 77) or not pt.note_tokens(3, 77):
        raise RuntimeError("KV pool too small for readmitted slot 3")
    return pt.decode_step_plan(list(range(8)))


def _moe_stack():
    sh = dict(n_tokens=64, d_model=128, d_ff=256)
    return plan_ir.concat(
        [plan_ir.moe_layer_plan(n_experts=8, top_k=2, dtype="int8",
                                layer=i, x="x" if i == 0 else
                                f"M{i-1}.out", **sh)
         for i in range(2)], name="moe_x2")


def _ssm_stack():
    return plan_ir.concat(
        [plan_ir.ssm_layer_plan(128, 128, 4, "int8", chunk=16, layer=i,
                                x="x" if i == 0 else f"S{i-1}.out")
         for i in range(2)], name="ssm_x2")


def _workloads():
    return [
        ("gemm1024", lambda: plan_ir.gemm_plan_cached(1024, 1024, 1024,
                                                      "int8")),
        ("bert-base.exact", lambda: model_stream_plan("bert-base")),
        ("bert-base.sampled", lambda: model_stream_schedule("bert-base")),
        ("moe.exact_x2", _moe_stack),
        ("ssm.exact_x2", _ssm_stack),
        ("decode_step", _decode_trace_plan),
    ]


def _events_of(plan):
    return plan.sampled_events if isinstance(plan, plan_ir.PlanSchedule) \
        else len(plan.events)


def main():
    rows = []
    report = {}
    for name, build in _workloads():
        t0 = time.perf_counter()
        plan = build()
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan.compile()
        compile_s = time.perf_counter() - t0
        events = _events_of(plan)
        wl = {"events": events, "build_s": round(build_s, 4),
              "compile_s": round(compile_s, 4), "modes": {}}
        tot_e = tot_c = 0.0
        for mode, dram_name in MODES:
            dram = DRAM(dram_name) if dram_name else None
            cfg = default_system(mode, dram=dram)
            t0 = time.perf_counter()
            rc = replay(cfg, plan, engine="compiled")
            wall_c = time.perf_counter() - t0
            t0 = time.perf_counter()
            re = replay(cfg, plan, engine="event")
            wall_e = time.perf_counter() - t0
            err = abs(rc.total_s - re.total_s) / max(re.total_s, 1e-30)
            assert err < 1e-9, (name, mode, err)
            tot_e += wall_e
            tot_c += wall_c
            wl["modes"][mode] = {
                "event_s": round(wall_e, 4),
                "compiled_s": round(wall_c, 4),
                "event_ev_per_s": round(events / max(wall_e, 1e-9)),
                "compiled_ev_per_s": round(events / max(wall_c, 1e-9)),
                "speedup": round(wall_e / max(wall_c, 1e-9), 2),
                "total_us": round(re.total_s * 1e6, 3),
            }
        wl["speedup_all_modes"] = round(tot_e / max(tot_c, 1e-9), 2)
        report[name] = wl
        rows.append((name, round(tot_c / len(MODES) * 1e6, 1),
                     f"events={events};"
                     f"speedup_all_modes={wl['speedup_all_modes']}x;"
                     f"compiled_ev_per_s="
                     f"{round(events * len(MODES) / max(tot_c, 1e-9))}"))
    report["_meta"] = {
        "note": "wall-clock of replay() per engine; compiled modes "
                "share one plan compile + trace analysis (memoized), "
                "so the first mode carries that one-time cost",
        "acceptance": "bert-base.exact speedup_all_modes >= 10x",
    }
    JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {JSON_PATH} (bert-base.exact all-modes speedup: "
          f"{report['bert-base.exact']['speedup_all_modes']}x)")
    emit(rows, "replay_engines")
    # drop the exact full-depth graph (order-100 MB with its compiled
    # arrays) so the rest of a benchmarks/run.py session isn't pinning it
    model_stream_plan.cache_clear()


if __name__ == "__main__":
    main()
