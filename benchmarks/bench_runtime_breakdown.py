"""Fig. 8: end-to-end runtime breakdown by operation class (ViT-Base)."""
from repro.accesys import workloads as W
from repro.accesys.system import (CPUModel, default_system,
                                  run_transformer_accel,
                                  run_transformer_composed,
                                  run_transformer_cpu)
from benchmarks.common import emit


def main():
    wl = W.transformer_trace("vit-base-16")
    rows = []
    base = run_transformer_cpu(wl)
    for k, v in base.breakdown().items():
        rows.append((f"cpu1.{k}", round(base.total_s * v * 1e6, 1),
                     f"share={v:.3f}"))
    neon = run_transformer_cpu(wl, simd=True)
    for k, v in neon.breakdown().items():
        rows.append((f"neon.{k}", round(neon.total_s * v * 1e6, 1),
                     f"share={v:.3f}"))
    acc = run_transformer_accel(default_system("DC"), wl)
    for k, v in acc.breakdown().items():
        rows.append((f"matrixflow.{k}", round(acc.total_s * v * 1e6, 1),
                     f"share={v:.3f}"))
    # Fig.-2 latency buckets from the composed StreamPlan replay
    # (descriptor / translation / transfer / compute / drain / host)
    plan_r = run_transformer_composed(default_system("DC"),
                                      "vit-base-16", n_layers=2)
    for k, v in plan_r.buckets().items():
        rows.append((f"plan2layer.{k}", round(plan_r.total_s * v * 1e6, 1),
                     f"share={v:.3f}"))
    emit(rows, "fig8_runtime_breakdown")


if __name__ == "__main__":
    main()
