"""Fig. 11 / Table 7: five DRAM technologies, device- vs host-attached."""
from repro.accesys.components import DRAM, DRAM_TECH
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import default_system
from benchmarks.common import emit


def main():
    rows = []
    for tech in DRAM_TECH:
        dev = simulate_gemm(default_system("DevMem", dram=DRAM(tech),
                                           dtype="int32"),
                            2048, 2048, 2048).total_s
        host = simulate_gemm(default_system("DM", dram=DRAM(tech),
                                            dtype="int32"),
                             2048, 2048, 2048).total_s
        rows.append((f"{tech}.device", round(dev * 1e6, 1),
                     f"bw={DRAM_TECH[tech][2]/1e9:.1f}GB/s"))
        rows.append((f"{tech}.host", round(host * 1e6, 1),
                     f"device_advantage={host / dev:.2f}x"))
    emit(rows, "fig11_memory_tech")


if __name__ == "__main__":
    main()
