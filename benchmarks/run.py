"""One benchmark per paper table/figure. Prints ``name,us_per_call,
derived`` CSV rows and writes artifacts/bench/*.csv."""
import importlib
import sys
import time

MODULES = [
    "bench_sa_ppa",            # Table 6
    "bench_gemm_precision",    # Fig 7a
    "bench_gemm_size",         # Fig 7b
    "bench_runtime_breakdown", # Fig 8
    "bench_roofline",          # Fig 9
    "bench_packet_size",       # Fig 10
    "bench_memory_tech",       # Fig 11 / Table 7
    "bench_interconnect",      # Fig 12
    "bench_nongemm",           # Fig 13
    "bench_tlb",               # Table 8
    "bench_e2e_models",        # Table 9
    "bench_kernels",           # Eq. 1 + streaming attention (wall-clock)
    "bench_serving",           # engine throughput + trace replay
    "bench_replay",            # compiled-vs-event engines -> BENCH_replay.json
    "bench_design_space",      # batched sweep -> BENCH_design_space.json
    "bench_serving_scale",     # streamed 1k/10k open-loop traces ->
    #                            BENCH_serving_scale.json
    "bench_moe_sweep",         # exact MoE expert x capacity sweep
    "bench_sampling_error",    # steady-state sampling error bars
]


def main() -> None:
    t0 = time.time()
    failures = []
    for name in MODULES:
        print(f"# --- {name} ---", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}", flush=True)
    print(f"# done in {time.time()-t0:.1f}s; {len(failures)} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
